"""Pallas TPU kernel: fused predict-only bank read path.

The paper's central efficiency claim is about *prediction*: once the state
is a fixed-size theta, serving a query is one O(D d) featurize plus one
O(D) dot — no growing dictionary, no state mutation. PRs 1-4 fused and
chunked the *update* path; this kernel gives the read path the same
treatment. For a bank of B tenants and a block of Q queries per tenant it
computes, in ONE launch,

    z      = s * cos(x_q @ W + b)        (featurize, shared map)
    y_hat  = theta_tenant . z            (predict; state read-only)

against a read-only theta — the serving hot loop at read:write ratios where
queries dominate (serve/snapshot.py holds that theta frozen between
publishes, so this kernel never races the trainer).

TPU mapping:
  * grid (bank_blocks, query_blocks) with the query axis minor: the
    ``(block_b, D)`` theta tile is pinned per bank block (index_map ignores
    the query index), so Pallas keeps it VMEM-resident across the WHOLE
    query block — theta HBM traffic is one read per launch instead of one
    per query (the bytes-moved crossover benchmarks/serve_bench.py models);
  * ``W (d, D)`` is grid-invariant exactly as in the update kernels — one
    HBM fetch per launch, reused by every (bank, query) block;
  * the featurize GEMM flattens the ``(block_b, block_q, d)`` query tile to
    ``(block_b * block_q, d)`` so the MXU sees one well-shaped matmul; the
    predict reduction is VPU work on the same tile.

Mixed precision (the ``precision=`` knob, contract in ``kernels/ref.py``):
``bf16`` casts the GEMM inputs to bf16 with f32 accumulation and stores the
feature block in bf16; the final reduction against theta accumulates in
f32. State stays f32 — predictions move, theta never does (per-family
tolerance pinned in tests/test_read_path.py).

Padding (all exact): the contraction dim d zero-pads (adds 0 to the
projection); padded D columns carry s == 0 so z is exactly 0 there and the
reduction is untouched; padded bank rows / query columns are sliced off.

VMEM per grid step: W d*D f32 + theta block_b*D f32 + the (block_b*block_q,
d + D) projection/feature tiles. Defaults (8, 64) keep the feature tile at
512*D f32 — 1 MiB at D=512, comfortably under budget with double-buffering.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels.ref import canon_precision, mp_project, mp_trig
from repro.kernels.rff_features import _ceil_to, _pad2

__all__ = ["rff_predict_kernel", "rff_bank_predict_pallas"]


def rff_predict_kernel(
    x_ref, w_ref, b_ref, s_ref, theta_ref, o_ref, *, precision=None
):
    """Grid point (i, j): query block j for bank block i on resident theta.

    The query index is minor, so ``theta_ref``'s tile (pinned to block
    (i, 0)) survives in VMEM across every query block of tenant block i.
    """
    bb, bq, dp = x_ref.shape
    xf = x_ref[...].reshape(bb * bq, dp)
    proj = mp_project(
        xf.astype(jnp.float32), w_ref[...].astype(jnp.float32), precision
    )
    z = mp_trig(
        proj,
        b_ref[...].astype(jnp.float32),
        s_ref[...].astype(jnp.float32),
        precision,
    )
    theta = theta_ref[...].astype(jnp.float32)  # (bb, D)
    zr = z.reshape(bb, bq, -1).astype(jnp.float32)
    o_ref[...] = jnp.sum(theta[:, None, :] * zr, axis=-1).astype(o_ref.dtype)


@functools.partial(
    jax.jit, static_argnames=("block_b", "block_q", "precision", "interpret")
)
def rff_bank_predict_pallas(
    theta: jax.Array,
    xq: jax.Array,
    w: jax.Array,
    b: jax.Array,
    s: jax.Array | None = None,
    *,
    block_b: int = 8,
    block_q: int = 64,
    precision: str | None = None,
    interpret: bool = False,
) -> jax.Array:
    """Fused predict-only read path for B tenants sharing one feature map.

    Args:
      theta: ``(B, D)`` per-tenant solutions (read-only).
      xq: ``(B, Q, d)`` a block of Q queries per tenant.
      w: ``(d, D)`` shared spectral matrix.
      b: ``(D,)`` shared phases.
      s: ``(D,)`` shared per-feature scales; None = Monte-Carlo
         ``sqrt(2/D)``.
      precision: None/"f32" (bitwise the oracle) or "bf16" (mixed-precision
        featurize, f32 accumulation — contract in kernels/ref.py).

    Returns:
      predictions ``(B, Q)``.
    """
    precision = canon_precision(precision)
    bsz, qlen, d = xq.shape
    dfeat = theta.shape[-1]
    assert theta.shape == (bsz, dfeat)
    assert w.shape == (d, dfeat) and b.shape == (dfeat,)
    if s is None:
        s = jnp.full((dfeat,), float((2.0 / dfeat) ** 0.5), jnp.float32)
    assert s.shape == (dfeat,)

    bb = min(block_b, _ceil_to(bsz, 8))
    bq = min(block_q, _ceil_to(qlen, 8))
    bp, qp = _ceil_to(bsz, bb), _ceil_to(qlen, bq)
    dp, np_ = _ceil_to(d, 128), _ceil_to(dfeat, 128)

    theta_p = _pad2(theta, bp, np_)
    xq_p = jnp.pad(xq, ((0, bp - bsz), (0, qp - qlen), (0, dp - d)))
    w_p = _pad2(w, dp, np_)
    b_p = jnp.pad(b, (0, np_ - dfeat))[None, :]  # (1, Np)
    s_p = jnp.pad(s, (0, np_ - dfeat))[None, :]  # (1, Np), padded scales 0

    grid = (bp // bb, qp // bq)  # q minor: theta resident across queries
    pred = pl.pallas_call(
        functools.partial(rff_predict_kernel, precision=precision),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bb, bq, dp), lambda i, j: (i, j, 0)),
            pl.BlockSpec((dp, np_), lambda i, j: (0, 0)),  # grid-invariant W
            pl.BlockSpec((1, np_), lambda i, j: (0, 0)),
            pl.BlockSpec((1, np_), lambda i, j: (0, 0)),
            pl.BlockSpec((bb, np_), lambda i, j: (i, 0)),  # resident theta
        ],
        out_specs=pl.BlockSpec((bb, bq), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((bp, qp), theta.dtype),
        interpret=interpret,
    )(xq_p, w_p, b_p, s_p, theta_p)
    return pred[:bsz, :qlen]
