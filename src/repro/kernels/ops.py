"""Public jit'd wrappers for the Pallas kernels, with backend dispatch.

On TPU the compiled Pallas kernels run natively; on CPU (this container) the
default is the pure-XLA reference path, with ``interpret=True`` Pallas
execution available for kernel-correctness tests. The API is stable across
backends so the model code never branches.

Dispatch observability: the serving-path ops are host wrappers around
their jitted cores. Each call reports to ``repro.obs.telemetry`` (live
launch / remainder-launch counters and the bytes-moved gauge from the
benches' closed-form models) and opens one ``kernel.<op>`` span on the
active tracer (``repro.obs.trace``) carrying shape / dtype / mode /
chunk attributes. Calls reached under an enclosing ``jax.jit`` trace
execute at *trace* time, so they are tagged ``traced=True`` and counted
under ``kernel.traces`` instead of live launches (the compiled program's
executions are counted by the tier that invokes it, e.g. the micro-batch
queue's per-flush dispatch record). With no active tracer the span is a
reusable null context — the untraced path costs a few dict operations.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.obs import telemetry as _telemetry
from repro.obs import trace as _trace
from repro.kernels import ref
from repro.kernels.chunking import (
    default_chunk_t,
    default_decode_block_t,
    time_blocks,
    unblock_time,
    valid_time_mask,
)
from repro.kernels.rff_features import rff_features_pallas
from repro.kernels.rff_predict import rff_bank_predict_pallas
from repro.kernels.rff_attention import (
    rff_attention_decode_block_pallas,
    rff_attention_pallas,
)
from repro.kernels.rff_klms_step import (
    rff_klms_bank_chunk_pallas,
    rff_klms_bank_step_pallas,
)
from repro.kernels.rff_krls_step import (
    rff_krls_bank_chunk_pallas,
    rff_krls_bank_step_pallas,
)
from repro.kernels.rff_scan import (
    rff_klms_chunk_elements_pallas,
    rff_krls_chunk_elements_pallas,
)
from repro.kernels.flash_attention import flash_attention_pallas

__all__ = [
    "default_backend",
    "rff_features",
    "rff_bank_predict",
    "rff_klms_bank_step",
    "rff_klms_bank_chunk",
    "rff_krls_bank_step",
    "rff_krls_bank_chunk",
    "rff_klms_chunk_elements",
    "rff_krls_chunk_elements",
    "rff_attention",
    "rff_attention_decode",
    "rff_attention_decode_block",
    "flash_attention",
]


def default_backend() -> str:
    return jax.default_backend()


def _use_pallas(mode: str) -> tuple[bool, bool]:
    """Resolve mode -> (use_pallas, interpret).

    ``fused`` / ``twopass`` are aliases for ``pallas`` / ``xla``: the fused
    single-program path vs the two-pass reference (feature map and update as
    separate passes with an HBM round-trip between them).
    """
    if mode == "auto":
        on_tpu = default_backend() == "tpu"
        return on_tpu, False
    if mode in ("pallas", "fused"):
        return True, default_backend() != "tpu"
    if mode == "interpret":
        return True, True
    if mode in ("xla", "twopass"):
        return False, False
    raise ValueError(f"unknown kernel mode {mode!r}")


def _ceil_div(a: int, b: int) -> int:
    return -(-a // b)


def _dispatch(
    op: str,
    lead,
    *,
    launches: int = 1,
    remainder: int = 0,
    bytes_moved: float | None = None,
    **attrs,
):
    """Record one dispatch-layer call for ``op`` and open its span.

    ``lead`` is the op's leading array argument: a ``jax.core.Tracer``
    there means this call site was reached under an enclosing jit trace
    (it compiles launches, it doesn't execute them), so it is tagged
    ``traced`` for both the telemetry counters and the span. Returns the
    ``kernel.<op>`` span context (the shared null context when no tracer
    is active).
    """
    traced = isinstance(lead, jax.core.Tracer)
    _telemetry.record_dispatch(
        op,
        launches=launches,
        remainder=remainder,
        bytes_moved=bytes_moved,
        traced=traced,
    )
    return _trace.span(
        f"kernel.{op}", traced=traced, launches=launches, **attrs
    )


@functools.partial(
    jax.jit,
    static_argnames=("mode", "block_m", "block_n", "block_k", "precision"),
)
def rff_features(
    x: jax.Array,
    w: jax.Array,
    b: jax.Array,
    s: jax.Array | None = None,
    *,
    mode: str = "auto",
    block_m: int = 128,
    block_n: int = 128,
    block_k: int = 128,
    precision: str | None = None,
) -> jax.Array:
    """Affine-trig feature map ``s * cos(x @ w + b)`` over arbitrary leading
    dims. ``s`` optional ``(D,)`` per-feature scales (the canonical form of
    every trig family in repro.features); None = Monte-Carlo ``sqrt(2/D)``.
    ``precision=None/"f32"`` is the bitwise-legacy path; ``"bf16"`` runs the
    GEMM in bf16 with f32 accumulation and emits bf16 features (the
    read-path contract documented in kernels/ref.py).
    """
    use_pallas, interpret = _use_pallas(mode)
    if not use_pallas:
        return ref.rff_features_ref(x, w, b, s, precision)
    lead = x.shape[:-1]
    x2 = x.reshape(-1, x.shape[-1])
    out = rff_features_pallas(
        x2, w, b, s,
        block_m=block_m, block_n=block_n, block_k=block_k,
        interpret=interpret, precision=precision,
    )
    return out.reshape(*lead, w.shape[-1])


@functools.partial(
    jax.jit, static_argnames=("mode", "block_b", "block_q", "precision")
)
def _rff_bank_predict_jit(
    theta, xq, w, b, s=None, *, mode, block_b, block_q, precision
):
    use_pallas, interpret = _use_pallas(mode)
    if not use_pallas:
        return ref.rff_bank_predict_ref(theta, xq, w, b, s, precision)
    return rff_bank_predict_pallas(
        theta, xq, w, b, s,
        block_b=block_b, block_q=block_q, precision=precision,
        interpret=interpret,
    )


def rff_bank_predict(
    theta: jax.Array,
    xq: jax.Array,
    w: jax.Array,
    b: jax.Array,
    s: jax.Array | None = None,
    *,
    mode: str = "auto",
    block_b: int = 8,
    block_q: int = 64,
    precision: str | None = None,
) -> jax.Array:
    """Fused predict-only read path: a ``(B, Q, d)`` query block per tenant
    against read-only ``theta (B, D)`` in one launch -> ``(B, Q)``.

    This is `core.bank.bank_predict` (one vmapped featurize+matvec per
    query) batched into one kernel: theta and W are fetched once per launch
    instead of once per query, and ``precision="bf16"`` drops the featurize
    GEMM to bf16 with f32 accumulation (contract in kernels/ref.py; state
    is read-only and stays f32). The serving read path of serve/snapshot.py
    and benchmarks/serve_bench.py.
    """
    bank, q, d = xq.shape
    bm = _telemetry.predict_read_bytes(bank, d, w.shape[-1], q)
    with _dispatch(
        "bank_predict", theta,
        bytes_moved=bm["fused_bytes"],
        shape=[bank, q, d], dfeat=w.shape[-1], dtype=str(theta.dtype),
        mode=mode, precision=precision,
    ):
        return _rff_bank_predict_jit(
            theta, xq, w, b, s,
            mode=mode, block_b=block_b, block_q=block_q, precision=precision,
        )


@functools.partial(jax.jit, static_argnames=("mode", "block_b"))
def _rff_klms_bank_step_jit(theta, x, y, w, b, mu, s=None, *, mode, block_b):
    use_pallas, interpret = _use_pallas(mode)
    if not use_pallas:
        return ref.rff_klms_bank_step_ref(theta, x, y, w, b, mu, s)
    return rff_klms_bank_step_pallas(
        theta, x, y, w, b, jnp.asarray(mu, theta.dtype), s,
        block_b=block_b, interpret=interpret,
    )


def rff_klms_bank_step(
    theta: jax.Array,
    x: jax.Array,
    y: jax.Array,
    w: jax.Array,
    b: jax.Array,
    mu: jax.Array | float,
    s: jax.Array | None = None,
    *,
    mode: str = "auto",
    block_b: int = 8,
) -> tuple[jax.Array, jax.Array, jax.Array]:
    """Fused featurize+predict+update KLMS step for a bank of B filters.

    theta (B, D), x (B, d), y (B,), shared w (d, D) / b (D,), mu scalar or
    (B,), s optional (D,) per-feature scales (None = sqrt(2/D)). Returns
    (theta_new, predictions, prior errors).
    """
    bank, d = x.shape
    bm = _telemetry.klms_chunk_bytes(bank, d, theta.shape[-1], 1)
    with _dispatch(
        "klms_step", theta,
        bytes_moved=bm["bytes_per_tick_model"],
        shape=[bank, d], dfeat=theta.shape[-1], dtype=str(theta.dtype),
        mode=mode,
    ):
        return _rff_klms_bank_step_jit(
            theta, x, y, w, b, mu, s, mode=mode, block_b=block_b
        )


@functools.partial(jax.jit, static_argnames=("mode", "block_b", "chunk"))
def _rff_klms_bank_chunk_jit(
    theta, xs, ys, w, b, mu, mask=None, s=None, *, mode, block_b, chunk
):
    use_pallas, interpret = _use_pallas(mode)
    mu_arr = jnp.asarray(mu, theta.dtype)
    bsz, tlen, _ = xs.shape
    if mask is None:
        mask = jnp.ones((bsz, tlen), theta.dtype)

    def launch(th, xc, yc, mc):
        if not use_pallas:
            return ref.rff_klms_bank_chunk_ref(
                th, xc, yc, w, b, mu_arr, mc, s
            )
        return rff_klms_bank_chunk_pallas(
            th, xc, yc, w, b, mu_arr, mc, s,
            block_b=block_b, interpret=interpret,
        )

    if chunk is None:
        chunk = default_chunk_t(
            bsz, theta.shape[-1], theta.dtype, input_dim=xs.shape[-1]
        )
    if tlen <= chunk:
        return launch(theta, xs, ys, mask)

    xs_c = time_blocks(xs, chunk, axis=1)
    ys_c = time_blocks(ys, chunk, axis=1)
    mask_c = time_blocks(mask.astype(theta.dtype), chunk, axis=1)

    def body(th, xym):
        th, preds, errs = launch(th, *xym)
        return th, (preds, errs)

    theta, (preds, errs) = jax.lax.scan(body, theta, (xs_c, ys_c, mask_c))
    return (
        theta,
        unblock_time(preds, tlen, axis=1),
        unblock_time(errs, tlen, axis=1),
    )


def rff_klms_bank_chunk(
    theta: jax.Array,
    xs: jax.Array,
    ys: jax.Array,
    w: jax.Array,
    b: jax.Array,
    mu: jax.Array | float,
    mask: jax.Array | None = None,
    s: jax.Array | None = None,
    *,
    mode: str = "auto",
    block_b: int = 8,
    chunk: int | None = None,
) -> tuple[jax.Array, jax.Array, jax.Array]:
    """T-chunked fused KLMS: advance a bank of B filters by T ticks at once.

    theta (B, D), xs (B, T, d), ys (B, T), shared w (d, D) / b (D,), mu
    scalar or (B,), mask optional (B, T) validity gate (1 = apply update),
    s optional (D,) per-feature scales (None = sqrt(2/D)).
    ``chunk`` bounds the ticks per kernel launch: ``chunk=k`` scans
    ceil(T/k) launches with a zero-masked final remainder; ``None`` picks
    the VMEM-budget-aware ``kernels.chunking.default_chunk_t`` for (B, D)
    (>= 512 for serving-sized banks, so short chunks still run in one
    launch). Returns (theta_new, predictions (B, T), errors (B, T)).
    """
    bank, tlen, d = xs.shape
    dfeat = theta.shape[-1]
    if chunk is None:
        chunk = default_chunk_t(bank, dfeat, theta.dtype, input_dim=d)
    launches = _ceil_div(tlen, chunk) if tlen > chunk else 1
    remainder = 1 if tlen > chunk and tlen % chunk else 0
    bm = _telemetry.klms_chunk_bytes(bank, d, dfeat, min(chunk, tlen))
    with _dispatch(
        "klms_chunk", theta,
        launches=launches, remainder=remainder,
        bytes_moved=bm["launch_bytes"] * launches
        + bm["stream_bytes_per_tick"] * tlen,
        shape=[bank, tlen, d], dfeat=dfeat, dtype=str(theta.dtype),
        mode=mode, chunk=chunk,
    ):
        return _rff_klms_bank_chunk_jit(
            theta, xs, ys, w, b, mu, mask, s,
            mode=mode, block_b=block_b, chunk=chunk,
        )


@functools.partial(jax.jit, static_argnames=("mode",))
def _rff_krls_bank_step_jit(theta, pmat, x, y, w, b, beta, s=None, *, mode):
    use_pallas, interpret = _use_pallas(mode)
    if not use_pallas:
        return ref.rff_krls_bank_step_ref(theta, pmat, x, y, w, b, beta, s)
    return rff_krls_bank_step_pallas(
        theta, pmat, x, y, w, b, jnp.asarray(beta, theta.dtype), s,
        interpret=interpret,
    )


def rff_krls_bank_step(
    theta: jax.Array,
    pmat: jax.Array,
    x: jax.Array,
    y: jax.Array,
    w: jax.Array,
    b: jax.Array,
    beta: jax.Array | float,
    s: jax.Array | None = None,
    *,
    mode: str = "auto",
) -> tuple[jax.Array, jax.Array, jax.Array, jax.Array]:
    """Fused featurize+predict+RLS-downdate step for a bank of B tenants.

    theta (B, D), pmat (B, D, D), x (B, d), y (B,), shared w (d, D) /
    b (D,), beta scalar or (B,), s optional (D,) per-feature scales.
    Returns (theta_new, pmat_new, predictions, prior errors).
    """
    bank, d = x.shape
    bm = _telemetry.krls_chunk_bytes(bank, d, theta.shape[-1], 1)
    with _dispatch(
        "krls_step", theta,
        bytes_moved=bm["bytes_per_tick_model"],
        shape=[bank, d], dfeat=theta.shape[-1], dtype=str(theta.dtype),
        mode=mode,
    ):
        return _rff_krls_bank_step_jit(
            theta, pmat, x, y, w, b, beta, s, mode=mode
        )


@functools.partial(jax.jit, static_argnames=("mode", "chunk"))
def _rff_krls_bank_chunk_jit(
    theta, pmat, xs, ys, w, b, beta, mask=None, s=None, *, mode, chunk
):
    use_pallas, interpret = _use_pallas(mode)
    beta_arr = jnp.asarray(beta, theta.dtype)
    bsz, tlen, _ = xs.shape
    if mask is None:
        mask = jnp.ones((bsz, tlen), theta.dtype)

    def launch(th, pm, xc, yc, mc):
        if not use_pallas:
            return ref.rff_krls_bank_chunk_ref(
                th, pm, xc, yc, w, b, beta_arr, mc, s
            )
        return rff_krls_bank_chunk_pallas(
            th, pm, xc, yc, w, b, beta_arr, mc, s, interpret=interpret
        )

    if chunk is None:
        chunk = default_chunk_t(
            bsz, theta.shape[-1], theta.dtype, pmat=True,
            input_dim=xs.shape[-1],
        )
    if tlen <= chunk:
        return launch(theta, pmat, xs, ys, mask)

    xs_c = time_blocks(xs, chunk, axis=1)
    ys_c = time_blocks(ys, chunk, axis=1)
    mask_c = time_blocks(mask.astype(theta.dtype), chunk, axis=1)

    def body(carry, xym):
        th, pm = carry
        th, pm, preds, errs = launch(th, pm, *xym)
        return (th, pm), (preds, errs)

    (theta, pmat), (preds, errs) = jax.lax.scan(
        body, (theta, pmat), (xs_c, ys_c, mask_c)
    )
    return (
        theta,
        pmat,
        unblock_time(preds, tlen, axis=1),
        unblock_time(errs, tlen, axis=1),
    )


def rff_krls_bank_chunk(
    theta: jax.Array,
    pmat: jax.Array,
    xs: jax.Array,
    ys: jax.Array,
    w: jax.Array,
    b: jax.Array,
    beta: jax.Array | float,
    mask: jax.Array | None = None,
    s: jax.Array | None = None,
    *,
    mode: str = "auto",
    chunk: int | None = None,
) -> tuple[jax.Array, jax.Array, jax.Array, jax.Array]:
    """T-chunked fused EW-RLS: advance a bank of B tenants by T ticks at once.

    theta (B, D), pmat (B, D, D), xs (B, T, d), ys (B, T), shared w (d, D) /
    b (D,), beta scalar or (B,), mask optional (B, T) validity gate, s
    optional (D,) per-feature scales (None = sqrt(2/D)).
    ``chunk`` bounds ticks per launch as in :func:`rff_klms_bank_chunk`
    (``None`` = VMEM-budget-aware default, with the ``(D, D)`` P tile
    charged against the budget).
    Returns (theta_new, pmat_new, predictions (B, T), errors (B, T)).
    """
    bank, tlen, d = xs.shape
    dfeat = theta.shape[-1]
    if chunk is None:
        chunk = default_chunk_t(
            bank, dfeat, theta.dtype, pmat=True, input_dim=d
        )
    launches = _ceil_div(tlen, chunk) if tlen > chunk else 1
    remainder = 1 if tlen > chunk and tlen % chunk else 0
    bm = _telemetry.krls_chunk_bytes(bank, d, dfeat, min(chunk, tlen))
    with _dispatch(
        "krls_chunk", theta,
        launches=launches, remainder=remainder,
        bytes_moved=bm["launch_bytes"] * launches
        + bm["stream_bytes_per_tick"] * tlen,
        shape=[bank, tlen, d], dfeat=dfeat, dtype=str(theta.dtype),
        mode=mode, chunk=chunk,
    ):
        return _rff_krls_bank_chunk_jit(
            theta, pmat, xs, ys, w, b, beta, mask, s, mode=mode, chunk=chunk
        )


@functools.partial(
    jax.jit, static_argnames=("mode", "chunk", "normalized", "eps")
)
def _rff_klms_chunk_elements_jit(
    xs, ys, w, b, mu, s=None, *, mode, chunk, normalized, eps
):
    use_pallas, interpret = _use_pallas(mode)
    tlen = xs.shape[0]
    dfeat = w.shape[-1]
    if chunk is None:
        chunk = default_chunk_t(
            1, dfeat, xs.dtype, input_dim=xs.shape[-1], elements=True
        )
    chunk = min(chunk, tlen)
    xs_c = time_blocks(xs, chunk)  # (nc, Tc, d)
    ys_c = time_blocks(ys, chunk)
    mask_c = valid_time_mask(tlen, chunk, jnp.float32)
    if not use_pallas:
        return ref.klms_chunk_elements_ref(
            xs_c, ys_c, w, b, mu, mask_c, s, normalized=normalized, eps=eps
        )
    return rff_klms_chunk_elements_pallas(
        xs_c, ys_c, w, b, mu, mask_c, s,
        normalized=normalized, eps=eps, interpret=interpret,
    )


def rff_klms_chunk_elements(
    xs: jax.Array,
    ys: jax.Array,
    w: jax.Array,
    b: jax.Array,
    mu: jax.Array | float,
    s: jax.Array | None = None,
    *,
    mode: str = "auto",
    chunk: int | None = None,
    normalized: bool = False,
    eps: float = 1e-6,
) -> tuple[jax.Array, jax.Array]:
    """Per-chunk composed KLMS affine elements for the replay scan.

    xs (T, d), ys (T,) — ONE replayed stream (a tenant's log), not a bank
    sweep; shared w (d, D) / b (D,), mu scalar, s optional (D,) per-feature
    scales (None = sqrt(2/D)). The stream is time-blocked into
    ceil(T/chunk) chunks (zero-masked remainder composing the identity) and
    each chunk folds into one ``theta -> a theta + v`` element — the
    blocked half of core/scan.py's ``mode="blocked"`` replay. ``chunk=None``
    picks the element-aware VMEM default (``default_chunk_t(...,
    elements=True)``). Returns ``(a (nc, D, D), v (nc, D))`` f32.
    """
    tlen, d = xs.shape
    dfeat = w.shape[-1]
    if chunk is None:
        chunk = default_chunk_t(1, dfeat, xs.dtype, input_dim=d,
                                elements=True)
    chunk = min(chunk, tlen)
    # One grid launch covers every chunk; the tail chunk is zero-masked
    # (composes the identity), not a separate launch.
    with _dispatch(
        "klms_elements", xs,
        shape=[tlen, d], dfeat=dfeat, chunks=_ceil_div(tlen, chunk),
        dtype=str(xs.dtype), mode=mode, chunk=chunk,
    ):
        return _rff_klms_chunk_elements_jit(
            xs, ys, w, b, mu, s,
            mode=mode, chunk=chunk, normalized=normalized, eps=eps,
        )


@functools.partial(jax.jit, static_argnames=("mode", "chunk"))
def _rff_krls_chunk_elements_jit(xs, ys, w, b, beta, s=None, *, mode, chunk):
    use_pallas, interpret = _use_pallas(mode)
    tlen = xs.shape[0]
    dfeat = w.shape[-1]
    if chunk is None:
        chunk = default_chunk_t(
            1, dfeat, xs.dtype, input_dim=xs.shape[-1], elements=True
        )
    chunk = min(chunk, tlen)
    xs_c = time_blocks(xs, chunk)  # (nc, Tc, d)
    ys_c = time_blocks(ys, chunk)
    mask_c = valid_time_mask(tlen, chunk, jnp.float32)
    if not use_pallas:
        return ref.krls_chunk_elements_ref(xs_c, ys_c, w, b, beta, mask_c, s)
    return rff_krls_chunk_elements_pallas(
        xs_c, ys_c, w, b, beta, mask_c, s, interpret=interpret
    )


def rff_krls_chunk_elements(
    xs: jax.Array,
    ys: jax.Array,
    w: jax.Array,
    b: jax.Array,
    beta: jax.Array | float,
    s: jax.Array | None = None,
    *,
    mode: str = "auto",
    chunk: int | None = None,
) -> tuple[jax.Array, jax.Array, jax.Array]:
    """Per-chunk composed KRLS decay elements for the replay scan.

    Layout as :func:`rff_klms_chunk_elements`; ``beta`` the scalar
    forgetting factor. Each chunk folds into one information-form element
    ``(g, phi, r)`` with masked remainder ticks composing ``(1, 0, 0)``.
    Returns ``(g (nc,), phi (nc, D, D), r (nc, D))`` f32.
    """
    tlen, d = xs.shape
    dfeat = w.shape[-1]
    if chunk is None:
        chunk = default_chunk_t(1, dfeat, xs.dtype, input_dim=d,
                                elements=True)
    chunk = min(chunk, tlen)
    with _dispatch(
        "krls_elements", xs,
        shape=[tlen, d], dfeat=dfeat, chunks=_ceil_div(tlen, chunk),
        dtype=str(xs.dtype), mode=mode, chunk=chunk,
    ):
        return _rff_krls_chunk_elements_jit(
            xs, ys, w, b, beta, s, mode=mode, chunk=chunk
        )


@functools.partial(
    jax.jit, static_argnames=("mode", "chunk", "normalize", "eps")
)
def rff_attention(
    phi_q: jax.Array,
    phi_k: jax.Array,
    v: jax.Array,
    *,
    mode: str = "auto",
    chunk: int = 256,
    normalize: bool = True,
    eps: float = 1e-6,
) -> jax.Array:
    """Causal linear attention over feature-mapped q/k. Shapes (BH, S, dv)."""
    use_pallas, interpret = _use_pallas(mode)
    if not use_pallas:
        # Chunked scan in pure XLA — same O(S·C·D) math as the kernel (the
        # quadratic ref is O(S^2) and would be unusable at 500k tokens).
        return _chunked_linear_attention_xla(
            phi_q, phi_k, v, chunk=chunk, normalize=normalize, eps=eps
        )
    return rff_attention_pallas(
        phi_q, phi_k, v,
        chunk=chunk, normalize=normalize, eps=eps, interpret=interpret,
    )


def _chunked_linear_attention_xla(phi_q, phi_k, v, *, chunk, normalize, eps):
    bh, s, d = phi_q.shape
    dv = v.shape[-1]
    c = min(chunk, s)
    assert s % c == 0
    n = s // c
    qc = phi_q.reshape(bh, n, c, d).astype(jnp.float32)
    kc = phi_k.reshape(bh, n, c, d).astype(jnp.float32)
    vc = v.reshape(bh, n, c, dv).astype(jnp.float32)
    mask = jnp.tril(jnp.ones((c, c), jnp.float32))

    def body(carry, inp):
        s_state, z_state = carry  # (bh, d, dv), (bh, d)
        q, k, vv = inp  # (bh, c, d), (bh, c, d), (bh, c, dv)
        a = jnp.einsum("btd,bsd->bts", q, k) * mask
        out = jnp.einsum("bts,bsv->btv", a, vv) + jnp.einsum(
            "btd,bdv->btv", q, s_state
        )
        if normalize:
            denom = jnp.sum(a, -1) + jnp.einsum("btd,bd->bt", q, z_state)
            out = out / (denom + eps)[..., None]
        s_state = s_state + jnp.einsum("bsd,bsv->bdv", k, vv)
        z_state = z_state + jnp.sum(k, axis=1)
        return (s_state, z_state), out

    init = (
        jnp.zeros((bh, d, dv), jnp.float32),
        jnp.zeros((bh, d), jnp.float32),
    )
    qn = jnp.moveaxis(qc, 1, 0)  # (n, bh, c, d) scan over chunks
    kn = jnp.moveaxis(kc, 1, 0)
    vn = jnp.moveaxis(vc, 1, 0)
    _, outs = jax.lax.scan(body, init, (qn, kn, vn))
    out = jnp.moveaxis(outs, 0, 1).reshape(bh, s, dv)
    return out.astype(phi_q.dtype)


@jax.jit
def rff_attention_decode(
    s_state: jax.Array,
    z_state: jax.Array,
    phi_q: jax.Array,
    phi_k: jax.Array,
    v: jax.Array,
    *,
    eps: float = 1e-6,
) -> tuple[jax.Array, jax.Array, jax.Array]:
    """One decode step from the fixed-size state (the RFFKLMS-style update).

    Args:
      s_state: ``(BH, D, dv)`` running sum of phi(k) v^T.
      z_state: ``(BH, D)`` running sum of phi(k).
      phi_q, phi_k: ``(BH, D)`` features of the new token.
      v: ``(BH, dv)`` value of the new token.

    Returns:
      (output ``(BH, dv)``, new_s, new_z). O(D·dv) per token, O(1) in context
      length — the KV cache never grows.
    """
    s_new = s_state + jnp.einsum("bd,bv->bdv", phi_k, v)
    z_new = z_state + phi_k
    num = jnp.einsum("bd,bdv->bv", phi_q, s_new)
    den = jnp.einsum("bd,bd->b", phi_q, z_new) + eps
    return num / den[:, None], s_new, z_new


@functools.partial(
    jax.jit,
    static_argnames=(
        "feature_kind", "mode", "block_t", "normalize", "eps", "precision",
    ),
)
def _rff_attention_decode_block_jit(
    s_state, z_state, q, k, v, w, b, s=None, *,
    feature_kind, mode, block_t, normalize, eps, precision,
):
    use_pallas, interpret = _use_pallas(mode)
    bh, tlen, dh = q.shape
    dv = v.shape[-1]
    dfeat = w.shape[-1]
    if s is None:
        s = ref.default_decode_scale(dfeat, feature_kind)
    if block_t is None:
        block_t = default_decode_block_t(dfeat, dv, dh, q.dtype)

    def launch(sm, zv, qc, kc, vc):
        if not use_pallas:
            return ref.rff_attention_decode_block_ref(
                sm, zv, qc, kc, vc, w, b, s,
                feature_kind=feature_kind, normalize=normalize, eps=eps,
                precision=precision,
            )
        return rff_attention_decode_block_pallas(
            sm, zv, qc, kc, vc, w, b, s,
            feature_kind=feature_kind, normalize=normalize, eps=eps,
            precision=precision, interpret=interpret,
        )

    s_state = s_state.astype(jnp.float32)
    z_state = z_state.astype(jnp.float32)
    if tlen <= block_t:
        return launch(s_state, z_state, q, k, v)

    # Full blocks under a scan, then one unpadded remainder launch: padded
    # ticks would corrupt the state (a PRF feature of a zero token is NOT
    # zero), so the remainder gets its own exact launch instead of a mask.
    nfull, rem = tlen // block_t, tlen % block_t
    cut = nfull * block_t

    def body(carry, qkv):
        sm, zv = carry
        out, sm, zv = launch(sm, zv, *qkv)
        return (sm, zv), out

    qf = jnp.moveaxis(q[:, :cut].reshape(bh, nfull, block_t, dh), 1, 0)
    kf = jnp.moveaxis(k[:, :cut].reshape(bh, nfull, block_t, dh), 1, 0)
    vf = jnp.moveaxis(v[:, :cut].reshape(bh, nfull, block_t, dv), 1, 0)
    (s_state, z_state), outs = jax.lax.scan(
        body, (s_state, z_state), (qf, kf, vf)
    )
    out = jnp.moveaxis(outs, 0, 1).reshape(bh, cut, -1)
    if rem:
        tail, s_state, z_state = launch(
            s_state, z_state, q[:, cut:], k[:, cut:], v[:, cut:]
        )
        out = jnp.concatenate([out, tail], axis=1)
    return out, s_state, z_state


def rff_attention_decode_block(
    s_state: jax.Array,
    z_state: jax.Array,
    q: jax.Array,
    k: jax.Array,
    v: jax.Array,
    w: jax.Array,
    b: jax.Array,
    s: jax.Array | None = None,
    *,
    feature_kind: str = "prf",
    mode: str = "auto",
    block_t: int | None = None,
    normalize: bool = True,
    eps: float = 1e-6,
    precision: str | None = None,
) -> tuple[jax.Array, jax.Array, jax.Array]:
    """Blocked decode: advance the fixed-size attention state by T tokens
    in ceil(T / block_t) launches instead of T.

    The fused featurize+tick schedule of
    :func:`repro.kernels.rff_attention.rff_attention_decode_block_pallas`:
    pre-projected q/k ``(BH, T, dh)`` and v ``(BH, T, dv)`` enter, the
    feature map (``feature_kind`` "trig" — the canonical affine-trig form
    of any as_trig family — or "prf") runs in-kernel under the read-path
    precision contract, and the per-head ``(D, dv)``/``(D,)`` state stays
    VMEM-resident across each block's strictly sequential ticks.

    ``block_t`` bounds tokens per launch; ``None`` picks the VMEM-budget
    default ``kernels.chunking.default_decode_block_t`` (which charges the
    resident state + W tiles). Longer decodes scan full blocks and finish
    with one remainder launch — no masked padding, so every launch is
    bitwise the per-token recursion at f32.

    Returns (outputs ``(BH, T, dv)`` f32, new_s, new_z) — the T=1 case is
    exactly :func:`rff_attention_decode` plus the in-kernel feature map.
    """
    bh, tlen, dh = q.shape
    dv = v.shape[-1]
    dfeat = w.shape[-1]
    if block_t is None:
        block_t = default_decode_block_t(dfeat, dv, dh, q.dtype)
    if tlen <= block_t:
        launches, remainder = 1, 0
    else:
        nfull, rem = tlen // block_t, tlen % block_t
        launches = nfull + (1 if rem else 0)
        remainder = 1 if rem else 0
    with _dispatch(
        "decode_block", q,
        launches=launches, remainder=remainder,
        shape=[bh, tlen, dh], dfeat=dfeat, dtype=str(q.dtype),
        mode=mode, block_t=block_t, feature_kind=feature_kind,
        precision=precision,
    ):
        return _rff_attention_decode_block_jit(
            s_state, z_state, q, k, v, w, b, s,
            feature_kind=feature_kind, mode=mode, block_t=block_t,
            normalize=normalize, eps=eps, precision=precision,
        )


@functools.partial(
    jax.jit, static_argnames=("mode", "block_q", "block_k", "causal")
)
def flash_attention(
    q: jax.Array,
    k: jax.Array,
    v: jax.Array,
    *,
    mode: str = "auto",
    block_q: int = 256,
    block_k: int = 256,
    causal: bool = True,
) -> jax.Array:
    """Exact blocked softmax attention, (BH, S, dh) layout."""
    use_pallas, interpret = _use_pallas(mode)
    if not use_pallas:
        return ref.flash_attention_ref(q, k, v, causal=causal)
    return flash_attention_pallas(
        q, k, v, block_q=block_q, block_k=block_k, causal=causal,
        interpret=interpret,
    )
