"""Pallas TPU kernels for the perf-critical compute layers.

``rff_features``: fused feature-map GEMM+cos (the paper's O(Dd) hot spot).
``rff_klms_bank_step``: fully-fused KLMS step (featurize+predict+update) for
a bank of B filters — the serving hot path; z never leaves VMEM.
``rff_krls_bank_step``: fully-fused EW-RLS step (featurize+predict+rank-1
P downdate) for a bank of B KRLS tenants — one VMEM-resident (D, D) tile
per tenant per tick.
``rff_bank_predict``: fused predict-only read path — a (B, Q, d) query
block per tenant against read-only theta in one launch, with a
``precision="bf16"`` mixed-precision featurize knob (serving hot path).
``rff_attention``: chunked causal linear attention with fixed-size VMEM state
(the paper's insight applied to the attention kernel).
``flash_attention``: blocked online-softmax attention (the full-attention
archs' train/prefill hot spot — the exact-kernel counterpart to RFF).

Each kernel has a pure-jnp oracle in ``ref.py`` and a backend-dispatching
wrapper in ``ops.py``; correctness is swept in tests with ``interpret=True``.
"""
from repro.kernels import ops, ref
from repro.kernels.chunking import default_chunk_t
from repro.kernels.ops import (
    flash_attention,
    rff_attention,
    rff_attention_decode,
    rff_bank_predict,
    rff_features,
    rff_klms_bank_chunk,
    rff_klms_bank_step,
    rff_krls_bank_chunk,
    rff_krls_bank_step,
)

__all__ = [
    "ops",
    "ref",
    "default_chunk_t",
    "rff_features",
    "rff_bank_predict",
    "rff_klms_bank_step",
    "rff_klms_bank_chunk",
    "rff_krls_bank_step",
    "rff_krls_bank_chunk",
    "rff_attention",
    "rff_attention_decode",
    "flash_attention",
]
