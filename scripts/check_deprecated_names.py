#!/usr/bin/env python
"""Fail CI on new *internal* uses of the deprecated serving factory names.

PR 7 collapsed the per-family serving factories into the learner-
parameterized facade (``repro.serve.make_server`` and friends); the old
names live on only as DeprecationWarning shims. This grep keeps the
codebase honest: source, benchmarks, examples and scripts must call the
facade, while the shim modules themselves (where the old names are
*defined*) and the tests (which pin the shims' equivalence and warning
behavior) are exempt.

Usage::

    python scripts/check_deprecated_names.py

Exits 1 listing every offending ``path:line`` if a deprecated name is
referenced outside the exempt set.
"""
from __future__ import annotations

import os
import re
import sys

DEPRECATED = [
    "make_bank_server",
    "make_krls_bank_server",
    "serve_bank_stream",
    "serve_krls_bank_stream",
    "make_chunked_bank_server",
    "make_chunked_krls_bank_server",
    "klms_micro_batch_queue",
    "krls_micro_batch_queue",
    "klms_snapshot_server",
    "krls_snapshot_server",
    "reset_tenants",
    "reset_krls_tenants",
]

# Where the shims are defined / re-exported, plus the tests that pin them.
EXEMPT = (
    "src/repro/serve/bank_loop.py",
    "src/repro/serve/queue.py",
    "src/repro/serve/snapshot.py",
    "src/repro/serve/api.py",
    "src/repro/serve/__init__.py",
    "tests/",
    "scripts/check_deprecated_names.py",
)

SCAN_DIRS = ("src", "benchmarks", "examples", "scripts")

# reset_krls_tenants contains reset_tenants — match whole identifiers.
PATTERN = re.compile(
    r"(?<![A-Za-z0-9_])(" + "|".join(DEPRECATED) + r")(?![A-Za-z0-9_])"
)


def main() -> int:
    root = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    offenders = []
    for scan in SCAN_DIRS:
        for dirpath, _, files in os.walk(os.path.join(root, scan)):
            for fname in sorted(files):
                if not fname.endswith(".py"):
                    continue
                path = os.path.join(dirpath, fname)
                rel = os.path.relpath(path, root)
                if rel.startswith(EXEMPT):
                    continue
                with open(path) as f:
                    for lineno, line in enumerate(f, 1):
                        m = PATTERN.search(line)
                        if m:
                            offenders.append(
                                f"{rel}:{lineno}: {m.group(1)} "
                                f"(use the serve.make_server facade)"
                            )
    if offenders:
        print(
            "deprecated serving factory names used outside shims/tests:",
            file=sys.stderr,
        )
        for o in offenders:
            print("  " + o, file=sys.stderr)
        return 1
    print(
        f"check_deprecated_names: clean "
        f"({len(DEPRECATED)} names, dirs: {', '.join(SCAN_DIRS)})"
    )
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
