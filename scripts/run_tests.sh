#!/usr/bin/env bash
# Tier-1 test runner. Usage:
#   scripts/run_tests.sh           # full suite (the tier-1 verify command)
#   scripts/run_tests.sh --fast    # skip @pytest.mark.slow tests (CI hot loop)
# Extra args are forwarded to pytest, e.g. scripts/run_tests.sh --fast -k bank
set -euo pipefail

REPO="$(cd "$(dirname "${BASH_SOURCE[0]}")/.." && pwd)"
export PYTHONPATH="${REPO}/src${PYTHONPATH:+:$PYTHONPATH}"

ARGS=(-x -q)
if [[ "${1:-}" == "--fast" ]]; then
  shift
  ARGS+=(-m "not slow")
fi

exec python -m pytest "${ARGS[@]}" "$@"
