#!/usr/bin/env bash
# Tier-1 test runner (CI-friendly). Usage:
#   scripts/run_tests.sh           # full suite (the tier-1 verify command)
#   scripts/run_tests.sh --fast    # skip @pytest.mark.slow tests (CI hot loop)
#   scripts/run_tests.sh --cov     # emit coverage.xml (requires pytest-cov)
# Extra args are forwarded to pytest, e.g. scripts/run_tests.sh --fast -k bank
# The script's exit code is pytest's exit code.
set -uo pipefail

REPO="$(cd "$(dirname "${BASH_SOURCE[0]}")/.." && pwd)"
export PYTHONPATH="${REPO}/src${PYTHONPATH:+:$PYTHONPATH}"
# Pin the platform so collection never trips on accelerator probing: CI
# runners (and most dev boxes) are CPU-only, and an unset JAX_PLATFORMS can
# abort at first jax import while it looks for TPU/GPU plugins. Override by
# exporting JAX_PLATFORMS yourself.
export JAX_PLATFORMS="${JAX_PLATFORMS:-cpu}"

ARGS=(-x -q)
while [[ $# -gt 0 ]]; do
  case "$1" in
    --fast)
      ARGS+=(-m "not slow")
      ;;
    --cov)
      if ! python -c "import pytest_cov" >/dev/null 2>&1; then
        echo "error: --cov requires pytest-cov (pip install pytest-cov)" >&2
        exit 2
      fi
      ARGS+=(--cov=repro --cov-report=xml --cov-report=term)
      ;;
    *)
      ARGS+=("$1")
      ;;
  esac
  shift
done

python -m pytest "${ARGS[@]}"
exit $?
