#!/usr/bin/env python
"""Bench-regression gate: fresh smoke run vs committed BENCH_*.json.

The committed baselines at the repo root are full-shape runs; CI's
``--tiny`` smokes re-run the same suites at small shapes into ``smoke/``.
Where a fresh record shares its identity columns with a committed record
(same suite, same shape keys), this gate compares the overlapping value
columns by class:

* **exact** — analytic model columns (bytes-moved, scan depth,
  element-buffer bytes, collectives/tick). Pure functions of shape: any
  drift means the closed form changed without regenerating baselines,
  or bench and model went out of sync. Tight (rel 1e-6).
* **error** — numerics floors (bf16 read-contract error, rel_err_*).
  Fresh must stay within ``factor`` x baseline AND under an absolute
  ceiling. Tight-ish: error floors don't move with runner load.
* **wall** — wall-clock columns. Generous band (default 10x baseline,
  widened further by ``--wall-slack``): CPU runners vary, but a
  same-shape record suddenly 10x slower is a real regression.
* **floor** — quality columns (hit rate): fresh >= baseline - slack.
* **bounds** — absolute checks on every fresh record regardless of any
  baseline join (probe health, degradation-event count). These keep the
  gate non-vacuous even for suites whose tiny shapes share no identity
  with the committed grid.

Records join on the suite's identity columns; a key absent from both
records matches (sweeps record only their own axes). Joins are strict on
values, so tiny-shape records silently skip suites whose grids don't
overlap — which is why ``--min-checks`` exists: if the total number of
individual comparisons performed falls below it, the gate fails as
vacuous instead of green-lighting nothing.

Usage (CI runs exactly this)::

    python scripts/check_bench_regress.py --fresh smoke --baseline . \
        --min-checks 20

``--fresh``/``--baseline`` are directories; files pair by name
(``BENCH_x.json`` <-> ``BENCH_x.json``). Suites without a spec below are
skipped with a note.
"""
from __future__ import annotations

import argparse
import glob
import json
import math
import os
import sys

# Per-suite comparison spec. "join" lists the identity columns (absent on
# both sides = match); column classes are described in the module
# docstring. Suites not listed here (run_* micro-bench suites with
# free-form "detail" strings) are skipped.
SPECS = {
    "replay_bench": {
        "join": ("bench", "family", "tlen", "d", "dfeat", "chunk"),
        "exact": (
            "sequential_depth", "scan_depth", "blocked_depth",
            "sequential_element_bytes", "scan_element_bytes",
            "blocked_element_bytes",
        ),
        "wall": {
            "sequential_us_per_rebuild": 10.0,
            "scan_us_per_rebuild": 10.0,
            "blocked_us_per_rebuild": 10.0,
        },
    },
    "chunk_bench": {
        "join": ("bench", "schedule", "bank", "dfeat", "combine_every",
                 "n_shards"),
        "exact": (
            "launch_bytes", "stream_bytes_per_tick", "bytes_per_tick_model",
            "collectives_per_tick_model", "payload_bytes_per_collective",
        ),
        "wall": {"us_per_tick": 10.0},
    },
    "serve_bench": {
        "join": ("bench", "family", "bank", "dfeat", "q"),
        "exact": (
            "adapter_bytes", "fused_bytes", "shared_bytes_per_launch",
            "stream_bytes_per_query",
        ),
        "error": {
            "max_abs_err": (8.0, 5e-2),
            "rms_err": (8.0, 1e-2),
        },
        "wall": {"adapter_us": 10.0, "fused_us": 10.0},
    },
    "decode": {
        "join": ("bench", "feature_kind", "attn", "context_len", "block_t"),
        "error": {
            "rel_err_out": (8.0, 5e-2),
            "rel_err_state": (8.0, 5e-2),
        },
        "wall": {"us_per_token": 10.0},
    },
    "recovery": {
        "join": ("bench", "learner", "fault", "action", "log_len",
                 "slots", "dfeat"),
        "wall": {
            "detect_us": 10.0,
            "repair_us": 10.0,
            "save_us": 10.0,
            "restore_us": 10.0,
        },
        # Self-healing invariants hold at ANY shape: every episode ends
        # healthy and every checkpoint round-trip is lossless.
        "bounds": {
            "end_healthy": ("min", 1.0),
            "state_bitwise": ("min", 1.0),
        },
    },
    "zipf": {
        "join": ("bench", "learner", "policy", "alpha", "ratio"),
        "wall": {"write_us.p99": 10.0, "read_us.p99": 10.0},
        "floor": {"hit_rate": 0.05},
        # Absolute floors on every fresh record — the numerics-health
        # columns the obs layer added must hold at ANY shape.
        "bounds": {
            "probes.finite": ("min", 1.0),
            "probes.bf16_read_error": ("max", 2e-2),
            "probes.degradation_events": ("max", 0),
            "hit_rate": ("min", 0.0),
            # Present only on --ckpt runs (CI smoke): the round-trip must
            # be lossless whenever it is exercised.
            "ckpt_bitwise": ("min", 1.0),
        },
    },
}


def _get(rec: dict, dotted: str):
    """Fetch a possibly-nested column ("probes.finite", "write_us.p99")."""
    cur = rec
    for part in dotted.split("."):
        if not isinstance(cur, dict) or part not in cur:
            return None
        cur = cur[part]
    return cur


def _join_key(rec: dict, keys: tuple) -> tuple:
    return tuple(rec.get(k) for k in keys)


def _load(path: str) -> dict | None:
    try:
        with open(path) as f:
            payload = json.load(f)
    except (OSError, json.JSONDecodeError):
        return None
    return payload if isinstance(payload, dict) else None


class Gate:
    """Accumulates comparisons and failures across suite pairs."""

    def __init__(self, wall_slack: float):
        self.wall_slack = wall_slack
        self.checks = 0
        self.failures: list[str] = []

    def _num(self, v):
        if isinstance(v, bool):
            return float(v)
        return v if isinstance(v, (int, float)) else None

    def compare_pair(self, name: str, fresh: dict, base: dict) -> None:
        suite = fresh.get("suite")
        spec = SPECS.get(suite)
        if spec is None:
            print(f"{name}: suite {suite!r} has no regression spec, skipped")
            return
        if base.get("suite") != suite:
            self.failures.append(
                f"{name}: fresh suite {suite!r} != baseline suite "
                f"{base.get('suite')!r}"
            )
            return
        jkeys = spec["join"]
        base_by_key: dict[tuple, dict] = {}
        for rec in base.get("records", []):
            if isinstance(rec, dict):
                base_by_key[_join_key(rec, jkeys)] = rec
        joined = 0
        for i, rec in enumerate(fresh.get("records", [])):
            if not isinstance(rec, dict):
                continue
            where = f"{name}: records[{i}] ({rec.get('bench')})"
            self._check_bounds(where, rec, spec.get("bounds", {}))
            b = base_by_key.get(_join_key(rec, jkeys))
            if b is None:
                continue
            joined += 1
            self._check_exact(where, rec, b, spec.get("exact", ()))
            self._check_error(where, rec, b, spec.get("error", {}))
            self._check_wall(where, rec, b, spec.get("wall", {}))
            self._check_floor(where, rec, b, spec.get("floor", {}))
        print(f"{name}: {joined} joined records, "
              f"{self.checks} cumulative checks")

    def _check_bounds(self, where: str, rec: dict, bounds: dict) -> None:
        for col, (kind, limit) in bounds.items():
            v = self._num(_get(rec, col))
            if v is None:
                continue
            self.checks += 1
            if kind == "min" and v < limit:
                self.failures.append(
                    f"{where}: {col} = {v} below floor {limit}"
                )
            elif kind == "max" and v > limit:
                self.failures.append(
                    f"{where}: {col} = {v} above ceiling {limit}"
                )

    def _check_exact(self, where, rec, base, cols) -> None:
        for col in cols:
            v, b = self._num(_get(rec, col)), self._num(_get(base, col))
            if v is None or b is None:
                continue
            self.checks += 1
            if not math.isclose(v, b, rel_tol=1e-6, abs_tol=1e-9):
                self.failures.append(
                    f"{where}: model column {col} = {v} != baseline {b} "
                    f"(closed form changed without regenerating baselines?)"
                )

    def _check_error(self, where, rec, base, cols) -> None:
        for col, (factor, ceiling) in cols.items():
            v, b = self._num(_get(rec, col)), self._num(_get(base, col))
            if v is None or b is None:
                continue
            self.checks += 1
            limit = max(b * factor, 1e-12)
            if v > limit:
                self.failures.append(
                    f"{where}: {col} = {v:.3g} exceeds {factor}x baseline "
                    f"({b:.3g})"
                )
            if v > ceiling:
                self.failures.append(
                    f"{where}: {col} = {v:.3g} above absolute ceiling "
                    f"{ceiling}"
                )

    def _check_wall(self, where, rec, base, cols) -> None:
        for col, factor in cols.items():
            v, b = self._num(_get(rec, col)), self._num(_get(base, col))
            if v is None or b is None or b <= 0:
                continue
            self.checks += 1
            limit = b * factor * self.wall_slack
            if v > limit:
                self.failures.append(
                    f"{where}: {col} = {v:.1f} slower than "
                    f"{factor * self.wall_slack:g}x baseline ({b:.1f})"
                )

    def _check_floor(self, where, rec, base, cols) -> None:
        for col, slack in cols.items():
            v, b = self._num(_get(rec, col)), self._num(_get(base, col))
            if v is None or b is None:
                continue
            self.checks += 1
            if v < b - slack:
                self.failures.append(
                    f"{where}: {col} = {v:.4f} regressed below baseline "
                    f"{b:.4f} - {slack}"
                )


def main(argv=None) -> int:
    parser = argparse.ArgumentParser(description=__doc__)
    parser.add_argument("--fresh", required=True,
                        help="directory of fresh smoke BENCH_*.json")
    parser.add_argument("--baseline", default=".",
                        help="directory of committed baselines")
    parser.add_argument("--min-checks", type=int, default=1,
                        help="fail as vacuous below this many comparisons")
    parser.add_argument("--wall-slack", type=float, default=1.0,
                        help="extra multiplier on every wall-clock band")
    args = parser.parse_args(argv)

    fresh_paths = sorted(glob.glob(os.path.join(args.fresh, "BENCH_*.json")))
    if not fresh_paths:
        print(f"check_bench_regress: no BENCH_*.json under {args.fresh!r}",
              file=sys.stderr)
        return 1

    gate = Gate(wall_slack=args.wall_slack)
    for fpath in fresh_paths:
        name = os.path.basename(fpath)
        bpath = os.path.join(args.baseline, name)
        fresh, base = _load(fpath), _load(bpath)
        if fresh is None:
            gate.failures.append(f"{name}: fresh artifact unreadable")
            continue
        if base is None:
            print(f"{name}: no committed baseline, skipped")
            continue
        if base.get("tiny"):
            gate.failures.append(
                f"{name}: committed baseline is a tiny run — baselines "
                f"must be full-shape"
            )
            continue
        gate.compare_pair(name, fresh, base)

    if gate.checks < args.min_checks:
        gate.failures.append(
            f"gate is vacuous: only {gate.checks} comparisons ran "
            f"(--min-checks {args.min_checks}) — did the smoke grids stop "
            f"overlapping the committed baselines?"
        )
    if gate.failures:
        for f in gate.failures:
            print(f"REGRESSION: {f}", file=sys.stderr)
        print(f"check_bench_regress: {len(gate.failures)} failure(s) over "
              f"{gate.checks} checks", file=sys.stderr)
        return 1
    print(f"check_bench_regress: OK ({gate.checks} comparisons, "
          f"{len(fresh_paths)} suites)")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
