#!/usr/bin/env python
"""CI chaos smoke: the whole fault matrix through a tiny policy server.

Cheap, deterministic end-to-end check of the self-healing loop — one
klms server, every injectable fault kind, and for each a hard assertion
of the full causal chain:

    fault -> exactly one ``probe.degraded`` event at the faulted flush's
    fold -> one quarantine episode -> a verified repair -> release ->
    healthy end state with no event ever re-firing.

(klms is the one family where "exactly one event" holds for every kind:
the fused kernel collapses Inf poison to NaN so only the ``finite``
probe fires; the generic-scan families can legitimately trip two probes
in the same fold, which the full chaos suite in tests/test_chaos.py
covers.) ``clock_skew`` is the global no-quarantine case: one event, one
reclock repair, skew back under threshold.

Usage::

    PYTHONPATH=src python scripts/chaos_smoke.py
"""
from __future__ import annotations

import sys
import time

import numpy as np

TENANT = 1

# kind -> (probe that must fire, terminal repair action)
MATRIX = {
    "nan_state": ("finite", "rebuild"),
    "asym_pmat": ("finite", "rebuild"),  # klms has no P: Inf-poison path
    "log_corrupt": ("finite", "reset"),
    "drop_flush": ("ticks_lag", "rebuild"),
}


def make_srv(make_server, rff, **extra):
    return make_server(
        "klms", feature_map=rff, bank=4, chunk=4, mu=0.3,
        policy="lru", log_capacity=256, **extra,
    )


def traffic(seed, n, tenants=3):
    rng = np.random.default_rng(seed)
    return [
        (
            int(rng.integers(0, tenants)),
            rng.standard_normal(3).astype(np.float32),
            float(rng.standard_normal()),
        )
        for _ in range(n)
    ]


def drive(srv, kind, Fault, FaultInjector, FaultPlan):
    """Warm -> inject at one flush -> tail; return events-at-detection."""
    arrivals = traffic(7, 60)
    warm, mid, tail = arrivals[:30], arrivals[30:42], arrivals[42:]
    if kind != "drop_flush":
        mid = [a for a in mid if a[0] != TENANT]
    for t, x, y in warm:
        srv.submit(t, x, y)
    srv.drain()
    assert srv.probe.total_events == 0, "degraded during warmup"

    inj = FaultInjector(
        srv, FaultPlan([Fault(kind, tenant=TENANT, at_flush=0)])
    ).attach()
    for t, x, y in mid:
        srv.submit(t, x, y)
    srv.flush()
    srv.drain()
    inj.detach()
    assert inj.applied, f"{kind}: fault never applied"
    at_detect = srv.probe.total_events

    for t, x, y in tail:
        srv.submit(t, x, y)
    srv.drain()
    return at_detect


def check_kind(kind, make_server, rff, faults) -> str:
    import jax

    Fault, FaultInjector, FaultPlan = faults
    srv = make_srv(make_server, rff, recovery=True)
    at_detect = drive(srv, kind, Fault, FaultInjector, FaultPlan)

    probe_name, action = MATRIX[kind]
    counters = srv.metrics.snapshot()["counters"]
    assert at_detect == 1, f"{kind}: {at_detect} events, expected exactly 1"
    assert srv.probe.events[0].probe == probe_name, (
        f"{kind}: fired {srv.probe.events[0].probe!r}, "
        f"expected {probe_name!r}"
    )
    assert srv.probe.total_events == at_detect, f"{kind}: event re-fired"
    assert counters["recovery.quarantines"] == 1, f"{kind}: quarantines"
    assert counters["recovery.releases"] == 1, f"{kind}: releases"
    assert counters[f"recovery.repairs{{action={action}}}"] == 1, (
        f"{kind}: expected one {action} repair; history="
        f"{srv.recovery.history}"
    )
    assert srv.recovery.history[-1]["verified"], f"{kind}: unverified repair"
    assert srv.recovery.quarantined == frozenset(), f"{kind}: still quarantined"
    for leaf in jax.tree.leaves(srv.queue.state):
        assert np.isfinite(np.asarray(leaf)).all(), f"{kind}: non-finite end"
    return f"{probe_name} -> {action}"


def check_clock_skew(make_server, rff, faults) -> str:
    Fault, FaultInjector, FaultPlan = faults
    srv = make_srv(
        make_server, rff,
        probe={"clock_skew": 0.25},
        recovery={"reference_clock": time.monotonic},
    )
    arrivals = traffic(8, 40)
    for t, x, y in arrivals[:30]:
        srv.submit(t, x, y)
    srv.drain()
    inj = FaultInjector(
        srv,
        FaultPlan([Fault("clock_skew", tenant=0, at_flush=0, magnitude=2.0)]),
    ).attach()
    for t, x, y in arrivals[30:]:
        srv.submit(t, x, y)
    srv.drain()
    inj.detach()
    counters = srv.metrics.snapshot()["counters"]
    assert srv.probe.total_events == 1, "clock_skew: expected exactly 1 event"
    assert srv.probe.events[0].probe == "clock_skew"
    assert counters["recovery.repairs{action=reclock}"] == 1
    assert srv.recovery.quarantined == frozenset()
    assert srv.recovery.measure_skew() < 0.25, "clock_skew: not reclocked"
    return "clock_skew -> reclock"


def main() -> int:
    import jax

    from repro.core.rff import sample_rff
    from repro.obs.faults import Fault, FaultInjector, FaultPlan
    from repro.serve import make_server

    rff = sample_rff(jax.random.PRNGKey(0), 3, 32, 1.0)
    faults = (Fault, FaultInjector, FaultPlan)
    for kind in MATRIX:
        outcome = check_kind(kind, make_server, rff, faults)
        print(f"chaos_smoke: {kind:<12} OK ({outcome})", flush=True)
    outcome = check_clock_skew(make_server, rff, faults)
    print(f"chaos_smoke: clock_skew   OK ({outcome})", flush=True)
    print("chaos_smoke: all faults detected, repaired, released")
    return 0


if __name__ == "__main__":
    sys.exit(main())
