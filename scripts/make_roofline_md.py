"""Generate the EXPERIMENTS.md roofline/dry-run tables from artifacts."""
import glob
import json
import os
import sys

DRY = sys.argv[1] if len(sys.argv) > 1 else "experiments/dryrun"


def fmt(v, digits=3):
    if v == 0:
        return "0"
    if v < 1e-3 or v >= 1e4:
        return f"{v:.2e}"
    return f"{v:.{digits}f}"


def main():
    cells = [json.load(open(f)) for f in sorted(glob.glob(os.path.join(DRY, "*.json")))]
    single = sorted(
        (c for c in cells if c["mesh"] == "16x16"),
        key=lambda c: (c["arch"], c["shape"]),
    )
    multi = {(c["arch"], c["shape"]): c for c in cells if c["mesh"] != "16x16"}

    print("### Roofline table (single pod, 16x16 = 256 chips)\n")
    print("| arch | shape | kind | compute s | memory s | collective s | dominant | MODEL_FLOPS | useful frac | what moves the dominant term |")
    print("|---|---|---|---|---|---|---|---|---|---|")
    hints = {
        ("train", "memory"): "larger microbatches / bf16 accumulators / fewer remat re-reads",
        ("train", "collective"): "cheaper TP collectives (shard or replicate the offending gate/proj)",
        ("train", "compute"): "MXU-aligned tiles; fuse feature map",
        ("prefill", "memory"): "blocked attention keeps O(S*blk); quantized KV",
        ("prefill", "collective"): "overlap layer AG with compute",
        ("decode", "memory"): "weights are re-read per token: batch more sequences / quantize weights",
        ("decode", "collective"): "reduce per-step combine size",
    }
    for c in single:
        r = c["roofline"]
        hint = hints.get((c["kind"], r["dominant"]), "")
        print(
            f"| {c['arch']} | {c['shape']} | {c['kind']} | {fmt(r['compute_s'])} | "
            f"{fmt(r['memory_s'])} | {fmt(r['collective_s'])} | **{r['dominant']}** | "
            f"{r['model_flops_total']:.2e} | {r['useful_flops_frac']:.3f} | {hint} |"
        )

    print("\n### Dry-run record (both meshes)\n")
    print("| arch | shape | mesh | compile s | microbatches | temp bytes/dev | collective bytes/dev | policy |")
    print("|---|---|---|---|---|---|---|---|")
    for c in sorted(cells, key=lambda c: (c["arch"], c["shape"], c["mesh"])):
        mem = c["memory"].get("temp_bytes")
        print(
            f"| {c['arch']} | {c['shape']} | {c['mesh']} | {c['compile_s']} | "
            f"{c.get('num_microbatches', '-')} | {mem/1e9 if mem else 0:.2f} GB | "
            f"{c['cost']['collective_bytes_per_device']/1e9:.2f} GB | {c['policy'][:40]} |"
        )


if __name__ == "__main__":
    main()
