#!/usr/bin/env python
"""Guard the committed BENCH_*.json baselines' shared schema.

Every committed baseline (and every CI smoke artifact) must stay loadable
by the same trajectory tooling, so this enforces the stable cross-suite
contract without freezing any suite's richer per-record fields:

* top-level keys ``suite`` (str), ``backend`` (str) and ``records``
  (non-empty list) are present;
* every record is an object carrying a ``bench`` name.

Suites may add columns freely — removing one of the shared keys (or
committing an empty/truncated run) is what this catches, as a cheap CI
step instead of a post-merge surprise when the perf-trajectory tooling
next reads the files.

Usage::

    python scripts/check_bench_schema.py [FILES...] [--trace TRACE.json]

With no arguments, checks every ``BENCH_*.json`` at the repo root.
``--trace`` additionally validates a Chrome trace-event export from the
observability layer (repro/obs/trace.py): loadable, well-formed events,
and spans present from every serve tier AND the kernel dispatch tier.
"""
from __future__ import annotations

import argparse
import glob
import json
import os
import sys

SHARED_KEYS = {"suite": str, "backend": str, "records": list}

# The zipf suite (benchmarks/zipf_bench.py) additionally promises the
# policy-comparison columns the README documents: percentile latencies and
# hit-rate per record, the numerics-health probe columns, and (for the
# committed full-shape baseline) coverage of >= 3 Zipf alphas and >= 2
# bank:tenant ratios. Smoke artifacts keep the per-record contract but may
# cover a single tiny config.
ZIPF_RECORD_KEYS = ("policy", "alpha", "ratio", "hit_rate", "write_us",
                    "read_us", "probes")
ZIPF_PROBE_KEYS = ("healthy", "finite", "bf16_read_error")
ZIPF_MIN_ALPHAS = 3
ZIPF_MIN_RATIOS = 2

# A traced serving run must surface every layer of the stack: the facade,
# the micro-batch queue, the snapshot tier, and the kernel dispatch layer
# (repro/kernels/ops.py) — a missing prefix means an instrumentation
# regression, not a formatting nit.
TRACE_REQUIRED_PREFIXES = ("serve.", "queue.", "snapshot.", "kernel.")
TRACE_EVENT_KEYS = ("name", "ph", "ts", "pid", "tid")

# The decode suite (benchmarks/decode_bench.py) promises the columns the
# README "Decode path" section documents, per bench kind; the committed
# full-shape baseline must additionally cover the sweep axes (>= 3 context
# lengths for the flat-vs-linear story, >= 2 block sizes for the launch-
# amortization story). Smoke artifacts keep the per-record contract but
# may cover fewer points.
DECODE_RECORD_KEYS = {
    "decode_context_sweep": ("attn", "context_len", "tokens_per_s",
                             "us_per_token"),
    "decode_block_sweep": ("block_t", "tokens_per_s", "us_per_token",
                           "speedup_vs_per_token"),
    "decode_bf16_error": ("feature_kind", "rel_err_out", "rel_err_state"),
}
DECODE_MIN_CONTEXTS = 3
DECODE_MIN_BLOCK_TS = 2

# The recovery suite (benchmarks/recovery_bench.py) promises the
# self-healing columns the README "Robustness" section documents, per
# record kind; the committed full-shape baseline must additionally cover
# every ladder rung and a log-length sweep for the rebuild rung. Smoke
# artifacts keep the per-record contract but may cover fewer points.
RECOVERY_RECORD_KEYS = {
    "recovery_repair": ("learner", "fault", "action", "log_len",
                        "detect_us", "repair_us", "end_healthy"),
    "ckpt_roundtrip": ("learner", "slots", "dfeat", "save_us",
                       "restore_us", "bytes", "state_bitwise"),
}
RECOVERY_REQUIRED_ACTIONS = ("resymmetrize", "rebuild", "reset")
RECOVERY_MIN_LOG_LENS = 2


def check_decode(path: str, payload: dict) -> list[str]:
    """Decode-suite-specific validation (called for suite == "decode")."""
    errors = []
    records = [r for r in payload.get("records", []) if isinstance(r, dict)]
    for i, rec in enumerate(records):
        for key in DECODE_RECORD_KEYS.get(rec.get("bench"), ()):
            if key not in rec:
                errors.append(f"{path}: records[{i}] missing {key!r}")
    if not payload.get("tiny"):
        contexts = {r.get("context_len") for r in records
                    if r.get("bench") == "decode_context_sweep"} - {None}
        block_ts = {r.get("block_t") for r in records
                    if r.get("bench") == "decode_block_sweep"} - {None}
        if len(contexts) < DECODE_MIN_CONTEXTS:
            errors.append(
                f"{path}: baseline covers {len(contexts)} context lengths, "
                f"needs >= {DECODE_MIN_CONTEXTS}"
            )
        if len(block_ts) < DECODE_MIN_BLOCK_TS:
            errors.append(
                f"{path}: baseline covers {len(block_ts)} block sizes, "
                f"needs >= {DECODE_MIN_BLOCK_TS}"
            )
    return errors


def check_recovery(path: str, payload: dict) -> list[str]:
    """Recovery-suite-specific validation (for suite == "recovery")."""
    errors = []
    records = [r for r in payload.get("records", []) if isinstance(r, dict)]
    for i, rec in enumerate(records):
        for key in RECOVERY_RECORD_KEYS.get(rec.get("bench"), ()):
            if key not in rec:
                errors.append(f"{path}: records[{i}] missing {key!r}")
    if not payload.get("tiny"):
        actions = {r.get("action") for r in records
                   if r.get("bench") == "recovery_repair"}
        for action in RECOVERY_REQUIRED_ACTIONS:
            if action not in actions:
                errors.append(
                    f"{path}: baseline never exercises the {action!r} "
                    f"ladder rung"
                )
        log_lens = {r.get("log_len") for r in records
                    if r.get("action") == "rebuild"} - {None}
        if len(log_lens) < RECOVERY_MIN_LOG_LENS:
            errors.append(
                f"{path}: rebuild covers {len(log_lens)} log lengths, "
                f"needs >= {RECOVERY_MIN_LOG_LENS}"
            )
        if not any(r.get("bench") == "ckpt_roundtrip" for r in records):
            errors.append(f"{path}: baseline has no ckpt_roundtrip record")
    return errors


def check_zipf(path: str, payload: dict) -> list[str]:
    """Zipf-suite-specific validation (called for suite == "zipf")."""
    errors = []
    records = [r for r in payload.get("records", []) if isinstance(r, dict)]
    for i, rec in enumerate(records):
        for key in ZIPF_RECORD_KEYS:
            if key not in rec:
                errors.append(f"{path}: records[{i}] missing {key!r}")
        for col in ("write_us", "read_us"):
            h = rec.get(col)
            if isinstance(h, dict):
                for p in ("p50", "p95", "p99"):
                    if p not in h:
                        errors.append(
                            f"{path}: records[{i}].{col} missing {p!r}"
                        )
        probes = rec.get("probes")
        if isinstance(probes, dict):
            for key in ZIPF_PROBE_KEYS:
                if key not in probes:
                    errors.append(
                        f"{path}: records[{i}].probes missing {key!r}"
                    )
    if not payload.get("tiny"):
        alphas = {r.get("alpha") for r in records} - {None}
        ratios = {r.get("ratio") for r in records} - {None}
        if len(alphas) < ZIPF_MIN_ALPHAS:
            errors.append(
                f"{path}: baseline covers {len(alphas)} alphas, "
                f"needs >= {ZIPF_MIN_ALPHAS}"
            )
        if len(ratios) < ZIPF_MIN_RATIOS:
            errors.append(
                f"{path}: baseline covers {len(ratios)} bank:tenant "
                f"ratios, needs >= {ZIPF_MIN_RATIOS}"
            )
    return errors


def check_trace(path: str) -> list[str]:
    """Validate one Chrome trace-event export (empty list = OK)."""
    errors = []
    try:
        with open(path) as f:
            payload = json.load(f)
    except (OSError, json.JSONDecodeError) as e:
        return [f"{path}: unreadable ({e})"]
    if not isinstance(payload, dict):
        return [f"{path}: top level is {type(payload).__name__}, not object"]
    events = payload.get("traceEvents")
    if not isinstance(events, list) or not events:
        return [f"{path}: 'traceEvents' missing or empty"]
    for i, ev in enumerate(events):
        if not isinstance(ev, dict):
            errors.append(f"{path}: traceEvents[{i}] is not an object")
            continue
        for key in TRACE_EVENT_KEYS:
            if key not in ev:
                errors.append(f"{path}: traceEvents[{i}] missing {key!r}")
        if ev.get("ph") == "X" and "dur" not in ev:
            errors.append(
                f"{path}: traceEvents[{i}] is a complete event without 'dur'"
            )
    names = {
        ev.get("name", "") for ev in events if isinstance(ev, dict)
    }
    for prefix in TRACE_REQUIRED_PREFIXES:
        if not any(n.startswith(prefix) for n in names):
            errors.append(
                f"{path}: no {prefix}* span — the "
                f"{prefix.rstrip('.')} tier is uninstrumented or the run "
                f"never exercised it"
            )
    return errors


def check_file(path: str) -> list[str]:
    """Return the schema violations for one BENCH_*.json (empty = OK)."""
    errors = []
    try:
        with open(path) as f:
            payload = json.load(f)
    except (OSError, json.JSONDecodeError) as e:
        return [f"{path}: unreadable ({e})"]
    if not isinstance(payload, dict):
        return [f"{path}: top level is {type(payload).__name__}, not object"]
    for key, typ in SHARED_KEYS.items():
        if key not in payload:
            errors.append(f"{path}: missing top-level key {key!r}")
        elif not isinstance(payload[key], typ):
            errors.append(
                f"{path}: {key!r} is {type(payload[key]).__name__}, "
                f"expected {typ.__name__}"
            )
    records = payload.get("records")
    if isinstance(records, list):
        if not records:
            errors.append(f"{path}: 'records' is empty")
        for i, rec in enumerate(records):
            if not isinstance(rec, dict):
                errors.append(f"{path}: records[{i}] is not an object")
            elif "bench" not in rec:
                errors.append(f"{path}: records[{i}] missing 'bench'")
    if payload.get("suite") == "zipf":
        errors.extend(check_zipf(path, payload))
    if payload.get("suite") == "decode":
        errors.extend(check_decode(path, payload))
    if payload.get("suite") == "recovery":
        errors.extend(check_recovery(path, payload))
    return errors


def main(argv=None) -> int:
    parser = argparse.ArgumentParser(description=__doc__)
    parser.add_argument("files", nargs="*",
                        help="BENCH_*.json payloads (default: repo root)")
    parser.add_argument("--trace", action="append", default=[],
                        metavar="PATH",
                        help="also validate a Chrome trace-event export")
    args = parser.parse_args(argv)
    if args.files:
        paths = args.files
    elif args.trace:
        paths = []
    else:
        root = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
        paths = sorted(glob.glob(os.path.join(root, "BENCH_*.json")))
    if not paths and not args.trace:
        print("check_bench_schema: no BENCH_*.json files found", file=sys.stderr)
        return 1
    failures = 0
    for path in paths:
        errors = check_file(path)
        if errors:
            failures += 1
            for e in errors:
                print(e, file=sys.stderr)
        else:
            n = len(json.load(open(path))["records"])
            print(f"{path}: OK ({n} records)")
    for path in args.trace:
        errors = check_trace(path)
        if errors:
            failures += 1
            for e in errors:
                print(e, file=sys.stderr)
        else:
            n = len(json.load(open(path))["traceEvents"])
            print(f"{path}: OK ({n} trace events)")
    return 1 if failures else 0


if __name__ == "__main__":
    raise SystemExit(main())
